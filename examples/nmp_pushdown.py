"""Near-memory-processing pushdown: all three paper operators end to end
(SELECT / pointer-chase KVS / regex), pure-JAX and Pallas-kernel paths,
with the interconnect economics of Fig. 5.

    PYTHONPATH=src python examples/nmp_pushdown.py
"""
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core.pushdown import (build_sharded_kvs, bulk_transfer_bytes,
                                 pushdown_bytes, pushdown_lookup,
                                 pushdown_regex, pushdown_select)
from repro.kernels import ops as kops
from repro.nmp import compile_regex, make_table

mesh = Mesh(np.array(jax.devices()).reshape(1), ("x",))

# --- SELECT (paper §5.4) ---------------------------------------------------
print("=== SELECT pushdown ===")
for sel in (0.01, 0.1, 1.0):
    table = make_table(jax.random.key(0), 8192, 16, sel)
    res = pushdown_select(mesh, "x", capacity=8192, table=table, x=0., y=1.)
    moved = pushdown_bytes(res, 16, 4)
    bulk = bulk_transfer_bytes(table)
    print(f"  selectivity {sel:5.0%}: moved {moved:>9,} B "
          f"vs bulk {bulk:>9,} B  ({bulk/max(moved,1):5.1f}x reduction)")

# the same scan through the Pallas kernel (TPU target, interpret on CPU):
packed, counts = kops.select(make_table(jax.random.key(1), 2048, 16, 0.1),
                             0.0, 1.0, block_rows=256)
print(f"  pallas select_scan: {int(counts.sum())} matches in "
      f"{counts.shape[0]} VMEM tiles (MXU one-hot compaction)")

# --- pointer chase (paper §5.5, the negative result) -----------------------
print("=== KVS pointer chase ===")
keys = np.arange(1, 8001, dtype=np.uint32)
vals = np.stack([keys.astype(np.float32)] * 4, 1)
for chain in (1, 16, 64):
    kvs = build_sharded_kvs(keys, vals, max(8000 // chain, 1), 1)
    q = jnp.asarray(np.random.RandomState(0).randint(1, 8000, 512),
                    jnp.uint32)
    t0 = time.perf_counter()
    v, found, steps = jax.block_until_ready(
        pushdown_lookup(mesh, "x", kvs, q, max_chain=chain + 4))
    dt = time.perf_counter() - t0
    print(f"  chain~{chain:3d}: found {int(found.sum())}/512, "
          f"mean hops {float(steps.mean()):5.1f}, {512/dt:8.0f} keys/s "
          f"(throughput ~ 1/chain — Fig. 6 reproduced)")

# --- regex (paper §5.6) ------------------------------------------------
print("=== regex pushdown ===")
rng = np.random.RandomState(2)
rows = rng.randint(97, 123, (4096, 32)).astype(np.uint8)
rows[:409, :6] = np.frombuffer(b"error!", np.uint8)
table8 = jnp.asarray(rows)
dfa = compile_regex("error!")
res = pushdown_regex(mesh, "x", 1024, dfa,
                     table8.astype(jnp.float32), 0, 32)
print(f"  'error!' matches: {int(res.moved_rows)} / 4096 "
      f"(DFA states: {dfa.n_states})")
m = kops.regex_match(jnp.asarray(dfa.transitions), jnp.asarray(dfa.accept),
                     table8, block_rows=256)
print(f"  pallas regex_dfa agrees: {int(m.sum())} matches")
print("done.")
