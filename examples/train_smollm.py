"""End-to-end training driver example: train a ~smollm-family model for a
few hundred steps with the full production stack (sharded train step,
AdamW + cosine, synthetic pipeline, async checkpointing, straggler monitor,
simulated failure + auto-resume).

    PYTHONPATH=src python examples/train_smollm.py [--steps 300]

(The assigned full configs are exercised via the multi-pod dry-run; this
example trains the reduced same-family config so it finishes on CPU.)
"""
import argparse
import shutil
import sys

sys.path.insert(0, "src")

import jax
import numpy as np
from jax.sharding import Mesh

from repro.configs import get_config
from repro.data import DataConfig
from repro.models import init_params
from repro.optim import OptimConfig
from repro.train import Trainer, TrainerConfig

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--fail-at", type=int, default=150,
                help="inject a simulated node failure at this step")
args = ap.parse_args()

CKPT = "/tmp/repro_example_ckpt"
shutil.rmtree(CKPT, ignore_errors=True)

cfg = get_config("smollm-360m", smoke=True)
mesh = Mesh(np.array(jax.devices()).reshape(1, 1), ("data", "model"))
ocfg = OptimConfig(peak_lr=5e-3, warmup_steps=20, total_steps=args.steps)
dcfg = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=8)
tcfg = TrainerConfig(steps=args.steps, ckpt_every=50, ckpt_dir=CKPT)


def make_trainer():
    params = init_params(jax.random.key(0), cfg)
    return Trainer(cfg, ocfg, tcfg, mesh, params, dcfg,
                   on_straggler=lambda e: print(f"  [straggler] {e}"))


t = make_trainer()
try:
    t.run(fail_at=args.fail_at, delay_at=args.steps // 3)
except RuntimeError as e:
    print(f"!! {e} — restarting from the latest valid checkpoint")
    t.saver.wait()
    t = make_trainer()
    result = t.run()
else:
    result = {"final_loss": t.metrics_log[-1]["loss"],
              "stragglers": t.monitor.events}

log = t.metrics_log
print(f"\nsteps run this process: {len(log)}")
print(f"loss: first5 {np.mean([m['loss'] for m in log[:5]]):.3f} -> "
      f"last5 {np.mean([m['loss'] for m in log[-5:]]):.3f}")
print(f"stragglers flagged: {len(t.monitor.events)}")
print("done — checkpoints in", CKPT)
